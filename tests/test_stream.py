"""repro.stream unit tests: cohort batching semantics (incl. adversarial
same-id interleavings), WAL framing/rotation/replay determinism, epoch
handoff, rebalance policy, and the checkpoint fsync_dir satellite."""
import json
import os

import jax
import numpy as np
import pytest

from repro.core.engine import SMTreeEngine
from repro.core.metric import pairwise
from repro.core.smtree import (OP_DELETE, OP_INSERT, ST_APPLIED, ST_NOTFOUND,
                               bulk_build)
from repro.data.datagen import clustered, uniform
from repro.stream import (EpochManager, MutationBatcher, StreamingEngine,
                          StreamingForest, WriteAheadLog, collect_stats,
                          cut_cohorts, needs_rebalance, rebalance_shards)
from repro.stream.wal import KIND_BATCH, KIND_REBALANCE, iter_wal


def _trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# cohort cutting
# ---------------------------------------------------------------------------
def test_cut_cohorts_no_conflicts_single_run():
    assert cut_cohorts(np.array([1, 2, 3, 4])) == [(0, 4)]


def test_cut_cohorts_splits_at_repeats():
    # 7 repeats at index 2 and again at 4
    assert cut_cohorts(np.array([7, 1, 7, 2, 7])) == [(0, 2), (2, 4), (4, 5)]
    assert cut_cohorts(np.array([], np.int32)) == [(0, 0)]


# ---------------------------------------------------------------------------
# batcher semantics
# ---------------------------------------------------------------------------
def test_batched_mixed_stream_matches_semantics():
    """Batched apply == sequential apply in terms of the live object set,
    tree invariants, and exact query results."""
    rng = np.random.default_rng(0)
    X = clustered(600, dims=6, seed=1)
    tree = bulk_build(X, capacity=8)
    extra = uniform(80, dims=6, seed=2)
    ops = np.concatenate([np.full(150, OP_DELETE), np.full(80, OP_INSERT)])
    oids = np.concatenate([rng.permutation(600)[:150],
                           600 + np.arange(80)]).astype(np.int32)
    xs = np.concatenate([X[oids[:150]], extra]).astype(np.float32)
    perm = rng.permutation(len(ops))
    ops, oids, xs = ops[perm].astype(np.int32), oids[perm], xs[perm]

    b = MutationBatcher(tree)
    res = b.apply(ops, xs, oids)
    assert (res.statuses == ST_APPLIED).all()
    # capacity 8 must push rows off the fast path; since PR 4/5 those
    # resolve as device splits/merges rather than host escalations
    assert res.n_escalated + res.n_split + res.n_merge > 0, \
        "want structure edits exercised (capacity 8)"
    eng = SMTreeEngine(b.tree)
    eng.validate()
    assert eng.n_objects == 600 - 150 + 80

    # queries over the mutated tree are exact vs brute force on the live set
    live_mask = np.ones(600, bool)
    live_mask[oids[ops == OP_DELETE]] = False
    live = np.concatenate([X[live_mask], extra])
    Q = uniform(16, dims=6, seed=3)
    got = eng.knn(Q, k=3, max_frontier=512)
    want = np.sort(pairwise("d_inf", Q, live), axis=1)[:, :3]
    np.testing.assert_allclose(np.asarray(got.dists), want, atol=1e-5)


def test_adversarial_same_id_interleaved():
    """insert/delete/insert of one id inside a single batch: cohort cuts
    keep the log order observable; the final state holds exactly one copy."""
    X = uniform(200, dims=4, seed=5)
    tree = bulk_build(X, capacity=6)   # tiny capacity: escalations likely
    b = MutationBatcher(tree)
    v1 = np.full(4, 0.25, np.float32)
    v2 = np.full(4, 0.75, np.float32)
    ops = np.array([OP_INSERT, OP_DELETE, OP_INSERT, OP_DELETE, OP_INSERT],
                   np.int32)
    oids = np.array([500, 500, 500, 500, 500], np.int32)
    xs = np.stack([v1, v1, v2, v2, v1])
    res = b.apply(ops, xs, oids)
    assert (res.statuses == ST_APPLIED).all()
    assert res.n_cohorts == 5   # every row conflicts with the previous
    eng = SMTreeEngine(b.tree)
    eng.validate()
    assert eng.n_objects == 200 + 1
    r = eng.range_search(v1[None, :], 0.0, max_results=4)
    assert 500 in np.asarray(r.ids)[0]


def test_adversarial_delete_then_reinsert_same_batch():
    """delete an existing object and re-insert the same id with a new
    vector, in one batch."""
    X = uniform(300, dims=5, seed=6)
    b = MutationBatcher(bulk_build(X, capacity=8))
    nv = np.full(5, 0.9, np.float32)
    ops = np.array([OP_DELETE, OP_INSERT], np.int32)
    res = b.apply(ops, np.stack([X[7], nv]), np.array([7, 7], np.int32))
    assert (res.statuses == ST_APPLIED).all()
    eng = SMTreeEngine(b.tree)
    eng.validate()
    assert eng.n_objects == 300
    r = eng.range_search(nv[None, :], 0.0, max_results=4)
    assert 7 in np.asarray(r.ids)[0]
    r = eng.range_search(X[7][None, :], 0.0, max_results=4)
    assert 7 not in np.asarray(r.ids)[0]


def test_delete_to_empty_then_reinsert():
    """Drain the tree completely through the batcher, then refill it."""
    X = uniform(120, dims=4, seed=7)
    b = MutationBatcher(bulk_build(X, capacity=8))
    res = b.apply(np.full(120, OP_DELETE, np.int32), X,
                  np.arange(120, dtype=np.int32))
    assert (res.statuses == ST_APPLIED).all()
    assert b.tree.n_objects == 0
    res = b.apply(np.full(120, OP_INSERT, np.int32), X,
                  np.arange(120, dtype=np.int32))
    assert (res.statuses == ST_APPLIED).all()
    eng = SMTreeEngine(b.tree)
    eng.validate()
    assert eng.n_objects == 120
    got = eng.knn(X[:10], k=1, max_frontier=256)
    np.testing.assert_allclose(np.asarray(got.dists)[:, 0], np.zeros(10),
                               atol=1e-6)


def test_notfound_delete_reported():
    X = uniform(100, dims=4, seed=8)
    b = MutationBatcher(bulk_build(X, capacity=8))
    res = b.apply(np.array([OP_DELETE], np.int32),
                  np.full((1, 4), 0.5, np.float32),
                  np.array([9999], np.int32))
    assert res.statuses[0] == ST_NOTFOUND
    assert b.tree.n_objects == 100


@pytest.mark.parametrize("metric", ["d_inf", "l2", "l1"])
def test_duplicate_vectors_under_all_metrics(metric):
    """Multiple objects sharing one vector (distance 0 to each other):
    batched insert, exact retrieval of every copy, then delete each copy
    by id — under all three metrics."""
    X = uniform(150, dims=6, seed=9)
    tree = bulk_build(X, capacity=8, metric=metric)
    b = MutationBatcher(tree)
    dup = X[42].copy()
    dup_ids = np.array([300, 301, 302, 303], np.int32)
    res = b.apply(np.full(4, OP_INSERT, np.int32),
                  np.tile(dup, (4, 1)), dup_ids)
    assert (res.statuses == ST_APPLIED).all()
    eng = SMTreeEngine(b.tree)
    eng.validate()
    r = eng.range_search(dup[None, :], 0.0, max_results=16,
                         max_frontier=256)
    got = set(int(i) for i in np.asarray(r.ids)[0] if i >= 0)
    assert {42, 300, 301, 302, 303} <= got
    # delete the duplicates one batch at a time (same vector, distinct ids)
    res = b.apply(np.full(4, OP_DELETE, np.int32), np.tile(dup, (4, 1)),
                  dup_ids)
    assert (res.statuses == ST_APPLIED).all()
    eng = SMTreeEngine(b.tree)
    eng.validate()
    r = eng.range_search(dup[None, :], 0.0, max_results=16,
                         max_frontier=256)
    got = set(int(i) for i in np.asarray(r.ids)[0] if i >= 0)
    assert 42 in got and not (got & set(dup_ids.tolist()))


# ---------------------------------------------------------------------------
# n_objects regression (satellite): dead nodes must not count
# ---------------------------------------------------------------------------
def test_n_objects_excludes_dead_nodes():
    """A freed node slot with stale valid bits (as a device-side batched
    merge would leave behind) must not inflate n_objects."""
    import dataclasses
    X = uniform(100, dims=4, seed=11)
    tree = bulk_build(X, capacity=8)
    n0 = tree.n_objects
    assert n0 == 100
    # kill a leaf without scrubbing its valid row
    leaf_ids = np.nonzero(np.asarray(tree.is_leaf & tree.alive))[0]
    victim = int(leaf_ids[-1])
    stale = dataclasses.replace(
        tree, alive=tree.alive.at[victim].set(False))
    dropped = int(np.asarray(tree.count)[victim])
    assert dropped > 0
    assert stale.n_objects == n0 - dropped


def test_n_objects_after_delete_with_merges():
    X = uniform(250, dims=4, seed=12)
    eng = SMTreeEngine.build(X, capacity=8)
    for i in range(200):   # force plenty of merges and frees
        assert eng.delete(X[i], i)
    assert eng.tree.n_objects == 50
    assert eng.tree.n_free_nodes > 0


# ---------------------------------------------------------------------------
# WAL
# ---------------------------------------------------------------------------
def test_wal_rotation_and_strict_manifest(tmp_path):
    d = str(tmp_path / "wal")
    wal = WriteAheadLog(d, segment_max_records=2)
    xs = np.zeros((3, 4), np.float32)
    for i in range(5):
        wal.append_batch(np.full(3, OP_INSERT, np.int8), xs + i,
                         np.arange(3) + 10 * i)
    wal.close()
    segs = sorted(n for n in os.listdir(d) if n.endswith(".wal"))
    assert len(segs) == 3   # 2 + 2 + 1 records
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)   # strict JSON parses
    assert [s["records"] for s in manifest["segments"]] == [2, 2]
    recs = list(iter_wal(d))
    assert [r.seq for r in recs] == list(range(5))
    np.testing.assert_array_equal(recs[3].xs, xs + 3)
    # tail replay skips up to the high-water mark
    assert [r.seq for r in iter_wal(d, after_seq=2)] == [3, 4]


def test_wal_reopen_continues_sequence(tmp_path):
    d = str(tmp_path / "wal")
    xs = np.zeros((2, 3), np.float32)
    with WriteAheadLog(d, segment_max_records=3) as wal:
        for _ in range(4):
            wal.append_batch(np.full(2, OP_INSERT, np.int8), xs,
                             np.arange(2))
    with WriteAheadLog(d, segment_max_records=3) as wal:
        assert wal.next_seq == 4
        wal.append_rebalance({"seed": 9})
    recs = list(iter_wal(d))
    assert [r.kind for r in recs] == [KIND_BATCH] * 4 + [KIND_REBALANCE]
    assert recs[-1].params == {"seed": 9}


def test_wal_torn_tail_tolerated(tmp_path):
    """A crash mid-append leaves a truncated frame; replay must stop
    cleanly at the last complete record instead of raising."""
    d = str(tmp_path / "wal")
    wal = WriteAheadLog(d)
    xs = np.ones((2, 3), np.float32)
    wal.append_batch(np.full(2, OP_INSERT, np.int8), xs, np.arange(2))
    wal.append_batch(np.full(2, OP_DELETE, np.int8), xs, np.arange(2))
    wal.close()
    seg = os.path.join(d, sorted(os.listdir(d))[-1])
    size = os.path.getsize(seg)
    with open(seg, "r+b") as f:
        f.truncate(size - 7)   # tear the last record's payload
    recs = list(iter_wal(d))
    assert len(recs) == 1 and recs[0].seq == 0


def test_wal_reopen_truncates_torn_tail(tmp_path):
    """Records appended after crash-recovery must be replayable: reopening
    over a torn tail truncates it, so the next append lands after the last
    complete record instead of behind unreadable garbage."""
    d = str(tmp_path / "wal")
    xs = np.ones((2, 3), np.float32)
    with WriteAheadLog(d) as wal:
        wal.append_batch(np.full(2, OP_INSERT, np.int8), xs, np.arange(2))
        wal.append_batch(np.full(2, OP_INSERT, np.int8), xs, np.arange(2))
    seg = os.path.join(d, sorted(n for n in os.listdir(d)
                                 if n.endswith(".wal"))[-1])
    with open(seg, "r+b") as f:
        f.truncate(os.path.getsize(seg) - 5)   # crash mid-append of seq 1
    with WriteAheadLog(d) as wal:
        assert wal.next_seq == 1               # torn seq-1 frame discarded
        wal.append_batch(np.full(2, OP_DELETE, np.int8), xs + 9,
                         np.arange(2))
    recs = list(iter_wal(d))
    assert [r.seq for r in recs] == [0, 1]
    np.testing.assert_array_equal(recs[1].xs, xs + 9)


def test_wal_corrupt_sealed_segment_raises(tmp_path):
    d = str(tmp_path / "wal")
    wal = WriteAheadLog(d, segment_max_records=1)   # every record seals
    xs = np.ones((2, 3), np.float32)
    wal.append_batch(np.full(2, OP_INSERT, np.int8), xs, np.arange(2))
    wal.append_batch(np.full(2, OP_INSERT, np.int8), xs, np.arange(2))
    wal.close()
    first = os.path.join(d, sorted(
        n for n in os.listdir(d) if n.endswith(".wal"))[0])
    with open(first, "r+b") as f:
        f.seek(os.path.getsize(first) - 3)
        f.write(b"\xff\xff\xff")
    with pytest.raises(ValueError, match="corrupt sealed"):
        list(iter_wal(d))


def test_wal_group_commit_concurrent_appends(tmp_path):
    """Group commit coalesces concurrent fsyncs but must lose nothing:
    every acknowledged append replays, seqs are unique and ordered, and
    segment rotation under concurrency keeps sealed segments durable."""
    import threading
    d = str(tmp_path / "wal")
    wal = WriteAheadLog(d, segment_max_records=16, sync=True,
                        group_commit=True)
    T, PER = 4, 24
    errs = []

    def worker(t):
        try:
            for i in range(PER):
                oids = (np.arange(3, dtype=np.int32)
                        + 1000 * t + 10 * i)
                wal.append_batch(np.full(3, OP_INSERT, np.int8),
                                 np.zeros((3, 4), np.float32), oids)
        except Exception as e:  # noqa: BLE001 — surfaced below
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(T)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    wal.close()
    assert not errs, errs
    recs = list(iter_wal(d))
    assert len(recs) == T * PER
    seqs = [r.seq for r in recs]
    assert seqs == sorted(seqs) and len(set(seqs)) == len(seqs)
    # every frame acknowledged is covered by some fsync
    assert wal._synced == wal._appended == T * PER


def test_wal_group_commit_single_thread_equivalent(tmp_path):
    """Single-threaded, group commit degenerates to fsync-per-append and
    replays identically to the plain sync mode."""
    xs = np.ones((2, 3), np.float32)
    logs = {}
    for name, group in (("plain", False), ("group", True)):
        d = str(tmp_path / name)
        wal = WriteAheadLog(d, sync=True, group_commit=group)
        for i in range(5):
            wal.append_batch(np.full(2, OP_INSERT, np.int8), xs,
                             np.arange(2 * i, 2 * i + 2))
        wal.close()
        logs[name] = list(iter_wal(d))
    for a, b in zip(logs["plain"], logs["group"]):
        assert a.seq == b.seq
        np.testing.assert_array_equal(a.oids, b.oids)


# ---------------------------------------------------------------------------
# snapshot + WAL tail replay determinism (single tree)
# ---------------------------------------------------------------------------
def test_snapshot_plus_tail_replay_is_bitwise(tmp_path):
    from repro.dist.checkpoint import CheckpointManager
    rng = np.random.default_rng(13)
    X = clustered(400, dims=6, seed=14)
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_max_records=3)
    ck = CheckpointManager(str(tmp_path / "ck"), async_write=False)
    eng = StreamingEngine(bulk_build(X, capacity=8), wal=wal, ckpt=ck)
    nid = 1000
    for step in range(8):
        n = 48
        kind = rng.random(n) < 0.45
        ops = np.where(kind, OP_INSERT, OP_DELETE).astype(np.int32)
        oids = np.where(kind, nid + np.arange(n),
                        rng.integers(0, 400, n)).astype(np.int32)
        xs = np.where(kind[:, None], rng.random((n, 6)).astype(np.float32),
                      X[np.minimum(oids, 399)])
        eng.apply(ops, xs.astype(np.float32), oids)
        nid += n
        if step == 3:
            eng.snapshot()
    restored = StreamingEngine.restore(str(tmp_path / "ck"), wal=wal)
    _trees_equal(eng.tree, restored.tree)
    SMTreeEngine(restored.tree).validate()


# ---------------------------------------------------------------------------
# epochs
# ---------------------------------------------------------------------------
def test_epoch_pin_survives_publish():
    mgr = EpochManager("v0")
    e0, t0 = mgr.acquire()
    assert (e0, t0) == (0, "v0")
    mgr.publish("v1")
    mgr.publish("v2")
    # pinned epoch still resident, intermediate unpinned version retired
    assert mgr.resident == [0, 2]
    assert mgr.current() == (2, "v2")
    mgr.release(e0)
    assert mgr.resident == [2]
    with pytest.raises(ValueError):
        mgr.release(2)


def test_epoch_keep_window():
    mgr = EpochManager("v0", keep=1)
    mgr.publish("v1")
    mgr.publish("v2")
    assert mgr.resident == [1, 2]


# ---------------------------------------------------------------------------
# rebalance
# ---------------------------------------------------------------------------
def _skewed_forest(n=800, shards=4, capacity=8):
    from repro.core.distributed import build_forest_trees
    X = clustered(n, dims=6, seed=15)
    trees = build_forest_trees(X, shards, capacity=capacity)
    sf = StreamingForest(trees, min_objects=64)
    victims = np.array([o for o in range(n) if o % shards == 0][:3 * n // 16])
    sf.delete_batch(X[victims], victims)
    return sf, X, victims


def test_rebalance_trigger_and_rebuild():
    sf, X, victims = _skewed_forest()
    stats = collect_stats(sf.trees)
    assert needs_rebalance(stats, max_skew=1.2, min_objects=64)
    before_ids = sorted(int(o) for o in sf.owner)
    trees, moved, params = rebalance_shards(sf.trees, seed=3)
    assert moved > 0
    after = collect_stats(trees)
    assert after.skew < stats.skew
    assert after.total == stats.total
    # object set is preserved exactly, every shard stays a valid SM-tree
    from repro.stream.rebalance import live_objects
    after_ids = sorted(int(o) for t in trees for o in live_objects(t)[1])
    assert after_ids == before_ids
    for t in trees:
        SMTreeEngine(t).validate()


def test_rebalance_deterministic():
    sf1, _, _ = _skewed_forest()
    sf2, _, _ = _skewed_forest()
    t1, m1, _ = rebalance_shards(sf1.trees, seed=5)
    t2, m2, _ = rebalance_shards(sf2.trees, seed=5)
    assert m1 == m2
    for a, b in zip(t1, t2):
        _trees_equal(a, b)


def test_rebalance_skips_balanced():
    from repro.core.distributed import build_forest_trees
    X = clustered(400, dims=6, seed=16)
    sf = StreamingForest(build_forest_trees(X, 4, capacity=8),
                         min_objects=64)
    assert not sf.maintenance()


# ---------------------------------------------------------------------------
# checkpoint fsync_dir satellite
# ---------------------------------------------------------------------------
def test_checkpoint_fsync_dir_roundtrip(tmp_path):
    import jax.numpy as jnp
    from repro.dist.checkpoint import (CheckpointManager, restore_checkpoint,
                                       save_checkpoint)
    tree = {"x": jnp.arange(6.0).reshape(2, 3)}
    d = str(tmp_path / "ck")
    save_checkpoint(d, 1, {"state": tree}, fsync_dir=True)
    out, manifest = restore_checkpoint(d, {"state": tree})
    assert manifest["step"] == 1
    np.testing.assert_array_equal(np.asarray(out["state"]["x"]),
                                  np.asarray(tree["x"]))
    mgr = CheckpointManager(d, keep=2, async_write=True, fsync_dir=True)
    mgr.save(2, {"state": tree})
    mgr.wait()
    assert mgr.latest_step() == 2
