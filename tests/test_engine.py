"""JAX SM-tree engine: equivalence vs brute force + the paper-faithful ref,
structural/SM invariants through bulk build, insert (with splits) and delete
(with merges), plus hypothesis property tests."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.engine import SMTreeEngine
from repro.core.metric import pairwise
from repro.data.datagen import clustered, uniform


def brute_knn_dists(X, Q, k):
    D = pairwise("d_inf", Q, X)
    return np.sort(D, axis=1)[:, :k]


def test_bulk_build_valid_and_knn_exact():
    X = clustered(2000, dims=8, seed=0)
    eng = SMTreeEngine.build(X, capacity=16)
    eng.validate()
    Q = uniform(32, dims=8, seed=1)
    res = eng.knn(Q, k=5, max_frontier=256)
    assert not np.asarray(res.overflow).any()
    want = brute_knn_dists(X, Q, 5)
    np.testing.assert_allclose(np.asarray(res.dists), want, atol=1e-5)


def test_knn_ids_match_brute_force():
    X = uniform(800, dims=4, seed=3)
    eng = SMTreeEngine.build(X, capacity=8)
    Q = X[:16] + 0.01
    res = eng.knn(Q, k=1, max_frontier=256)
    D = pairwise("d_inf", Q, X)
    want_ids = D.argmin(axis=1)
    got = np.asarray(res.ids)[:, 0]
    # ties possible: compare distances
    np.testing.assert_allclose(np.asarray(res.dists)[:, 0],
                               D[np.arange(16), want_ids], atol=1e-5)
    assert (got == want_ids).mean() > 0.9


def test_range_search_matches_brute_force():
    X = clustered(1500, dims=6, seed=5)
    eng = SMTreeEngine.build(X, capacity=16)
    Q = X[::300].copy()
    r = 0.08
    res = eng.range_search(Q, r, max_results=256, max_frontier=256)
    assert not np.asarray(res.overflow).any()
    D = pairwise("d_inf", Q, X)
    for qi in range(len(Q)):
        want = set(np.nonzero(D[qi] <= r)[0].tolist())
        got = set(int(i) for i in np.asarray(res.ids)[qi] if i >= 0)
        assert got == want


def test_zero_radius_finds_self():
    X = clustered(500, dims=8, seed=7)
    eng = SMTreeEngine.build(X, capacity=8)
    res = eng.range_search(X[::50], 0.0, max_results=8)
    for row, want in zip(np.asarray(res.ids), range(0, 500, 50)):
        assert want in row.tolist()


def test_incremental_insert_with_splits():
    X = uniform(400, dims=5, seed=11)
    eng = SMTreeEngine.empty(dim=5, capacity=8, max_nodes=512)
    for i, x in enumerate(X):
        eng.insert(x, i)
        if i % 130 == 0:
            eng.validate()
    eng.validate()
    assert eng.n_objects == 400
    res = eng.knn(X[:20], k=1, max_frontier=256)
    np.testing.assert_allclose(np.asarray(res.dists)[:, 0],
                               np.zeros(20), atol=1e-6)


def test_delete_with_merges_and_collapse():
    X = uniform(300, dims=4, seed=13)
    eng = SMTreeEngine.build(X, capacity=8)
    eng.validate()
    for i in range(250):
        assert eng.delete(X[i], i)
        if i % 60 == 0:
            eng.validate()
    eng.validate()
    assert eng.n_objects == 50
    res = eng.knn(X[250:270], k=1, max_frontier=256)
    np.testing.assert_allclose(np.asarray(res.dists)[:, 0],
                               np.zeros(20), atol=1e-6)
    # deleted objects are gone
    res = eng.range_search(X[:250], 0.0, max_results=4)
    ids = np.asarray(res.ids)
    for i in range(250):
        assert i not in ids[i]


def test_delete_not_found():
    X = uniform(100, dims=4, seed=17)
    eng = SMTreeEngine.build(X, capacity=8)
    assert not eng.delete(np.full(4, 0.5, np.float32), 1234)


def test_engine_query_results_match_ref_impl():
    """Engine and paper-faithful ref return the same kNN distances."""
    from repro.core.ref_impl import SMTree
    X = clustered(1200, dims=10, seed=19)
    eng = SMTreeEngine.build(X[:, :10], capacity=16)
    ref = SMTree(dim=10, capacity=16, n_dims=10)
    for i, x in enumerate(X[:, :10]):
        ref.insert(x, i)
    Q = uniform(10, dims=10, seed=2)
    res = eng.knn(Q, k=10, max_frontier=512)
    for qi, q in enumerate(Q):
        want = np.array([d for d, _ in ref.knn_query(q, 10)])
        np.testing.assert_allclose(np.asarray(res.dists)[qi], want, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31 - 1), st.integers(2, 6),
       st.sampled_from([6, 9, 16]))
def test_property_interleaved_ops_keep_invariants(seed, dim, cap):
    """Random interleaved insert/delete keeps every SM-tree invariant."""
    rng = np.random.default_rng(seed)
    n = 120
    X = rng.random((n, dim)).astype(np.float32)
    eng = SMTreeEngine.empty(dim=dim, capacity=cap, max_nodes=256)
    live = {}
    nid = 0
    for _ in range(200):
        if not live or rng.random() < 0.65:
            eng.insert(X[nid % n], nid)
            live[nid] = nid % n
            nid += 1
        else:
            oid = int(rng.choice(list(live)))
            assert eng.delete(X[live.pop(oid)], oid)
    eng.validate()
    assert eng.n_objects == len(live)
    # every live object findable at distance 0
    some = list(live.items())[:10]
    if some:
        Q = np.stack([X[v] for _, v in some])
        res = eng.range_search(Q, 0.0, max_results=16, max_frontier=128)
        ids = np.asarray(res.ids)
        for row, (oid, _) in enumerate(some):
            assert oid in ids[row]


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_property_knn_exact_when_no_overflow(seed):
    rng = np.random.default_rng(seed)
    X = rng.random((500, 5)).astype(np.float32)
    eng = SMTreeEngine.build(X, capacity=12, seed=seed % 1000)
    Q = rng.random((8, 5)).astype(np.float32)
    res = eng.knn(Q, k=3, max_frontier=512)
    assert not np.asarray(res.overflow).any()
    np.testing.assert_allclose(np.asarray(res.dists),
                               brute_knn_dists(X, Q, 3), atol=1e-5)


def test_page_hits_below_brute_force():
    """Pruning must beat scanning: page hits per query < total leaf count."""
    X = clustered(4000, dims=6, seed=23)
    eng = SMTreeEngine.build(X, capacity=32)
    n_leaves = int(np.asarray(eng.tree.is_leaf & eng.tree.alive).sum())
    res = eng.knn(X[:32], k=1, max_frontier=512)
    assert float(np.asarray(res.page_hits).mean()) < 0.8 * n_leaves
