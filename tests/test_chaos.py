"""Seeded chaos drill: the acceptance scenario for the replication plane.

Leader killed mid-batch (a torn half-frame in its WAL — the classic crash
mid-append) with 5% frame drop/reorder injected into the shipping layer.
The drill must hold, bit-for-bit, under every pinned seed:

  * the replica is promoted under a strictly higher fencing token,
  * its ``ledger_digest`` matches the pre-kill leader's last
    *acknowledged* state,
  * zero acknowledged-write loss (every acked seq replays; the torn,
    never-acknowledged batch is cleanly absent, not half-applied),
  * reads are served throughout — degraded mode stamped on tickets while
    leaderless — and writes fail fast, then flow again after promotion.

Determinism is the point: all randomness comes from the seed (numpy rng
for data, ``FaultPlan(seed=...)`` for the fault schedule), so a CI
failure replays locally from the same seed.
"""
import numpy as np
import pytest

from repro.core.smtree import OP_INSERT, bulk_build
from repro.serve.frontend import FrontendConfig, ServeFrontend
from repro.serve.router import LeaderUnavailable, ReplicaRouter
from repro.stream import (FencedOut, StreamingEngine, WriteAheadLog,
                          iter_wal, ledger_digest)
from repro.stream.faults import FaultInjector, FaultPlan
from repro.stream.lease import FenceGuard, LeaseStore, promote
from repro.stream.transport import ShippedReplica, WalShipServer
from repro.stream.wal import KIND_BATCH, WalRecord, _encode, _scan_dir

DIM = 6
SEEDS = [101, 202, 303]


class ManualClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


@pytest.mark.parametrize("seed", SEEDS)
def test_failover_drill(tmp_path, seed):
    rng = np.random.default_rng(seed)
    clock = ManualClock()
    store = LeaseStore(str(tmp_path / "lease"), ttl_s=5.0, clock=clock)
    grant = store.try_acquire("leader")

    X = rng.random((300, DIM)).astype(np.float32)
    tree0 = bulk_build(X, capacity=8)
    wal = WriteAheadLog(str(tmp_path / "wal"), segment_max_records=3,
                        fence=FenceGuard(store, "leader", grant.token))
    leader = StreamingEngine(tree0, wal=wal)
    fe = ServeFrontend(leader, FrontendConfig(cohort_width=4, slo_ms=5.0,
                                              k=3, max_frontier=256)).start()

    # 5% drop + 5% reorder on every shipped chunk, seeded
    fault = FaultInjector(FaultPlan(seed=seed, drop_p=0.05, reorder_p=0.05))
    # small chunks => many frames through the injector, so the 5% rates
    # fire plenty of times per run under every pinned seed
    srv = WalShipServer(str(tmp_path / "wal"), wal=wal, fault=fault,
                        chunk_bytes=64, max_chunks=256).start()
    rep = ShippedReplica(StreamingEngine(tree0), srv.address,
                         str(tmp_path / "mirror"), seed=seed)
    router = ReplicaRouter(fe, [rep], k=3, max_frontier=256)

    # -- phase 1: acknowledged traffic, replica shipping behind ----------
    acked = []                      # (seq, oids) per acknowledged batch
    n_batches = int(rng.integers(4, 8))
    for i in range(n_batches):
        oids = np.arange(1000 + 16 * i, 1016 + 16 * i, dtype=np.int32)
        res, token = router.mutate(np.full(16, OP_INSERT, np.int32),
                                   rng.random((16, DIM)).astype(np.float32),
                                   oids)
        acked.append((token.wal_seq, oids))
    seq, dg = ledger_digest(leader)         # last acknowledged state
    assert seq == acked[-1][0]

    # -- phase 2: kill mid-batch at a random frame -----------------------
    # the in-flight, never-acknowledged batch dies as a torn half-frame
    # (crash mid-append), cut at a seeded point inside the frame
    ops = np.full(16, OP_INSERT, np.int8)
    xs = rng.random((16, DIM)).astype(np.float32)
    torn_oids = np.arange(9000, 9016, dtype=np.int32)
    frame = _encode(WalRecord(KIND_BATCH, seq + 1, ops=ops, oids=torn_oids,
                              xs=xs))
    cut = int(rng.integers(1, len(frame) - 1))
    wal.close()
    names = _scan_dir(str(tmp_path / "wal"))
    import os
    with open(os.path.join(str(tmp_path / "wal"), names[-1]), "ab") as f:
        f.write(frame[:cut])
    fe.stop()                               # leader process is gone
    router.mark_leader_down()

    # -- phase 3: reads keep flowing, degraded-stamped; writes bounce ----
    q = rng.random(DIM).astype(np.float32)
    tk = router.query(q)
    tk.result(30)
    assert tk.mode == "degraded"
    assert tk.staleness >= 0
    with pytest.raises(LeaderUnavailable):
        router.mutate(np.full(1, OP_INSERT, np.int32),
                      np.zeros((1, DIM), np.float32),
                      np.array([99], np.int32))

    # -- phase 4: promote under a higher fence ---------------------------
    clock.t = 6.0                           # the dead leader's lease lapses
    promo = promote(rep, store, "follower-1", target=(seq, dg),
                    drain_timeout=60.0)
    assert promo.lease.token > grant.token
    assert promo.digest == dg               # bitwise = zero acked loss
    assert promo.applied_seq == seq

    # every acknowledged batch is in the authoritative (mirror) log; the
    # torn batch is cleanly absent — rejected, not half-applied
    mirror_recs = {r.seq: r for r in iter_wal(str(tmp_path / "mirror"))}
    for s, oids in acked:
        np.testing.assert_array_equal(mirror_recs[s].oids, oids)
    assert seq + 1 not in mirror_recs
    assert promo.wal.next_seq == seq + 1

    # a resurrected stale leader cannot append under its old fence
    zombie = WriteAheadLog(str(tmp_path / "wal"),
                           fence=FenceGuard(store, "leader", grant.token))
    with pytest.raises(FencedOut):
        zombie.append_batch(np.full(1, OP_INSERT, np.int8),
                            np.zeros((1, DIM), np.float32),
                            np.array([1], np.int32))

    # -- phase 5: the promoted follower serves writes again --------------
    fe2 = ServeFrontend(promo.lease and rep.follower,
                        FrontendConfig(cohort_width=4, slo_ms=5.0, k=3,
                                       max_frontier=256)).start()
    router.set_leader(fe2)
    res, token = router.mutate(np.full(4, OP_INSERT, np.int32),
                               rng.random((4, DIM)).astype(np.float32),
                               np.arange(7000, 7004, dtype=np.int32))
    assert token.wal_seq == seq + 1         # numbering continues, no gap
    tk = router.query(q, session=token)
    tk.result(30)
    assert tk.mode == "leader"
    fe2.stop()
    rep.stop()
    srv.stop()
    # the chaos actually happened: injected faults fired this run
    assert fault.counts["drop"] + fault.counts["reorder"] > 0
