"""Multi-device integration tests (8 host CPU devices via subprocess so the
main pytest process keeps its single-device backend)."""
import os
import subprocess
import sys

import pytest

WORKER = os.path.join(os.path.dirname(__file__), "_dist_worker.py")

SCENARIOS = [
    "forest_knn",
    "forest_brute_matches_tree",
    "forest_delete",
    "forest_stream",
    "forest_device_splits",
    "forest_device_merges",
    "forest_migration_mesh",
    "forest_knn_cohort_parity",
    "forest_parent_prune_parity",
    "replica_forest_mesh",
    "promote_follower_mesh",
    "train_step_sharded",
    "elastic_reshard",
    "compressed_psum",
    "moe_ep_equivalence",
]


@pytest.mark.parametrize("scenario", SCENARIOS)
def test_scenario(scenario):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(WORKER), "..", "src")
    res = subprocess.run([sys.executable, WORKER, scenario],
                         capture_output=True, text=True, env=env,
                         timeout=900)
    assert res.returncode == 0, \
        f"{scenario} failed:\nSTDOUT:{res.stdout[-2000:]}\nSTDERR:{res.stderr[-4000:]}"
    assert f"PASS {scenario}" in res.stdout
