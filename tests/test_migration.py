"""Incremental background rebalancing (DESIGN.md §16).

The PR-9 acceptance drill plus the unit surface around it: deterministic
bounded migration plans, the free-ring-pressure trigger, typed geometry
errors, epoch-exactly-once visibility through migration steps, and the
replay contract — snapshot + WAL tail restore is bitwise identical to the
straight-line run even when the "crash" lands between migration steps
(partial plan in the WAL).
"""
import time

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import smtree
from repro.core.distributed import build_forest_trees
from repro.core.engine import SMTreeEngine
from repro.core.smtree import OP_DELETE, OP_INSERT, bulk_build
from repro.data.datagen import clustered, uniform
from repro.dist.checkpoint import CheckpointManager
from repro.stream import (GeometryMismatch, MigrationPlan, StreamingForest,
                          WriteAheadLog, collect_stats, needs_rebalance,
                          plan_migration, rebalance_shards, tree_digest)
from repro.stream.rebalance import ShardStats, live_objects
from repro.stream.wal import (KIND_MIGRATION_PLAN, KIND_MIGRATION_STEP,
                              iter_wal)

DIM = 8


def _forest_live_ids(trees):
    out = []
    for t in trees:
        out.extend(int(o) for o in live_objects(t)[1])
    return sorted(out)


def _trees_equal(a, b):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _skewed_forest(n=800, shards=4, capacity=8, *, seed=31, **kw):
    X = clustered(n, dims=DIM, seed=seed)
    trees = build_forest_trees(X, shards, capacity=capacity)
    kw.setdefault("max_skew", 1.3)
    kw.setdefault("min_objects", 64)
    sf = StreamingForest(trees, rebalance_mode="incremental", **kw)
    victims = np.asarray([o for o in range(n) if o % shards < 2], np.int32)
    sf.delete_batch(X[victims], victims)
    return sf, X, victims


# ---------------------------------------------------------------------------
# smtree primitives: extract + batch move
# ---------------------------------------------------------------------------
def test_extract_objects_matches_live_set():
    X = uniform(300, dims=DIM, seed=1)
    t = bulk_build(X, capacity=8)
    ids = np.asarray([0, 7, 123, 299, 10_000], np.int32)
    vecs, found = smtree.extract_objects(t, ids)
    found = np.asarray(found)
    assert found.tolist() == [True, True, True, True, False]
    np.testing.assert_array_equal(np.asarray(vecs)[:4], X[ids[:4]])
    # absent rows come back zero-filled, not garbage
    np.testing.assert_array_equal(np.asarray(vecs)[4], np.zeros(DIM))


def test_move_objects_rehomes_batch():
    X = uniform(400, dims=DIM, seed=2)
    donor = bulk_build(X[:200], ids=np.arange(200), capacity=8)
    receiver = bulk_build(X[200:], ids=np.arange(200, 400), capacity=8)
    ids = np.asarray([3, 11, 42, 777], np.int32)   # 777 absent
    d2, r2, moved = smtree.move_objects(donor, receiver, ids)
    assert np.asarray(moved).tolist() == [True, True, True, False]
    assert d2.n_objects == 197 and r2.n_objects == 203
    d_ids = set(live_objects(d2)[1].tolist())
    r_ids = set(live_objects(r2)[1].tolist())
    for o in (3, 11, 42):
        assert o not in d_ids and o in r_ids
    SMTreeEngine(d2).validate()
    SMTreeEngine(r2).validate()


# ---------------------------------------------------------------------------
# planner: deterministic, bounded, stop-world-pairing math
# ---------------------------------------------------------------------------
def test_plan_migration_deterministic_and_bounded():
    sf, _, _ = _skewed_forest()
    p1 = plan_migration(sf.trees, seed=7, step_objects=32)
    p2 = plan_migration(sf.trees, seed=7, step_objects=32)
    assert p1 == p2
    assert p1.steps and p1.total > 0
    seen = []
    for s in p1.steps:
        assert 0 < len(s.oids) <= 32
        assert s.donor != s.receiver
        seen.extend(s.oids)
    assert len(seen) == len(set(seen))       # each object scheduled once
    # round-trips through the WAL param encoding exactly
    assert MigrationPlan.from_params(p1.to_params()) == p1


def test_plan_matches_stop_world_object_assignment():
    """The plan's object→receiver map is the stop-the-world pairing."""
    sf, _, _ = _skewed_forest()
    plan = plan_migration(sf.trees, seed=3, step_objects=10_000)
    planned = {o: s.receiver for s in plan.steps for o in s.oids}
    before = {s: set(live_objects(t)[1].tolist())
              for s, t in enumerate(sf.trees)}
    rebuilt, moved, _ = rebalance_shards(sf.trees, seed=3)
    assert moved == plan.total
    for s, t in enumerate(rebuilt):
        for o in live_objects(t)[1].tolist():
            if o not in before[s]:            # arrived via rebalancing
                assert planned[int(o)] == s


def test_balanced_forest_plans_empty():
    X = clustered(400, dims=DIM, seed=4)
    trees = build_forest_trees(X, 4, capacity=8)
    assert plan_migration(trees, seed=0).steps == ()


# ---------------------------------------------------------------------------
# satellite: geometry provenance is a typed error, not a divergent shard
# ---------------------------------------------------------------------------
def test_geometry_mismatch_typed_error():
    X = uniform(200, dims=DIM, seed=5)
    a = bulk_build(X[:100], ids=np.arange(100), capacity=8)
    b = bulk_build(X[100:], ids=np.arange(100, 200), capacity=8,
                   metric="l2")
    with pytest.raises(GeometryMismatch):
        rebalance_shards([a, b], seed=0)
    with pytest.raises(GeometryMismatch):
        plan_migration([a, b], seed=0)
    c = bulk_build(X[100:], ids=np.arange(100, 200), capacity=16)
    with pytest.raises(GeometryMismatch):
        plan_migration([a, c], seed=0)


# ---------------------------------------------------------------------------
# satellite: free-ring pressure fires the trigger before ring exhaustion
# ---------------------------------------------------------------------------
def test_free_ring_pressure_trigger():
    hist = np.asarray([[0, 0, 0, 30], [0, 0, 0, 10]], np.int64)
    stats = ShardStats(live_counts=np.asarray([240, 80], np.int64),
                       fill_hist=hist,
                       free_nodes=np.asarray([2, 22], np.int64))
    # skew 241/81 < 3.1: the skew-only policy stays quiet...
    assert not needs_rebalance(stats, max_skew=3.1, min_objects=64)
    # ...but shard 0 is over target with 2/32 free nodes: pressure fires
    assert needs_rebalance(stats, max_skew=3.1, min_objects=64,
                           free_floor=1 / 8)
    # a *balanced-but-starved* forest is not a rebalancing problem
    # (nothing to shed) — that stays with headroom growth
    even = ShardStats(live_counts=np.asarray([160, 160], np.int64),
                      fill_hist=hist,
                      free_nodes=np.asarray([2, 2], np.int64))
    assert not needs_rebalance(even, max_skew=3.1, min_objects=64,
                               free_floor=1 / 8)


def test_free_ring_pressure_near_exhausted_ring_regression():
    """Real near-exhausted ring: a tightly-allocated donor shard trips the
    pressure trigger and migration drains it without a mid-batch grow."""
    X = uniform(600, dims=DIM, seed=6)
    donor = bulk_build(X[:500], ids=np.arange(500), capacity=4, slack=1.02)
    receiver = bulk_build(X[500:], ids=np.arange(500, 600), capacity=4)
    stats = collect_stats([donor, receiver])
    frac = stats.free_nodes / (stats.fill_hist.sum(axis=1)
                               + stats.free_nodes)
    assert frac[0] < 1 / 8                     # genuinely near-exhausted
    assert not needs_rebalance(stats, max_skew=6.0, min_objects=64)
    assert needs_rebalance(stats, max_skew=6.0, min_objects=64,
                           free_floor=1 / 8)
    sf = StreamingForest([donor, receiver], max_skew=6.0, min_objects=64,
                         rebalance_mode="incremental", free_floor=1 / 8,
                         headroom_frac=None, migration_step_objects=64)
    assert sf.maintenance()                    # pressure, not skew, fired
    while sf.maintenance():
        pass
    after = collect_stats(sf.trees)
    assert after.live_counts[0] < stats.live_counts[0]
    # shedding surplus reclaimed ring slots on the pressured shard
    assert after.free_nodes[0] > stats.free_nodes[0]


# ---------------------------------------------------------------------------
# the acceptance drill: skew >= 4 drains to <= 1.2 in bounded steps while
# kNN keeps serving, worst pause well under the stop-world rebuild
# ---------------------------------------------------------------------------
def test_incremental_drill_acceptance():
    n, shards = 1600, 4
    X = clustered(n, dims=DIM, seed=7)
    trees = build_forest_trees(X, shards, capacity=8)
    victims = np.asarray([o for o in range(n) if o % shards < 2], np.int32)
    victims = victims[:int(0.8 * len(victims))]

    def _fresh():
        f = StreamingForest([t for t in trees], max_skew=1.2,
                            min_objects=64, rebalance_mode="incremental",
                            migration_step_objects=64)
        f.delete_batch(X[victims], victims)
        return f

    # warm leg: the first steps pay one-time jit compilation for the
    # extract/move kernels, which is not pause time (bench methodology)
    warm = _fresh()
    while warm.maintenance():
        pass

    sf = _fresh()
    before = collect_stats(sf.trees)
    assert before.skew >= 4.0

    # stop-world baseline cost on the identical forest
    sw = StreamingForest([t for t in trees], max_skew=1.2, min_objects=64)
    sw.delete_batch(X[victims], victims)
    t0 = time.perf_counter()
    assert sw.maintenance()
    stop_world_s = time.perf_counter() - t0

    alive = np.asarray(sorted(set(range(n)) - set(victims.tolist())))
    queries = X[alive[:32]]
    pauses, total_moved = [], 0
    while True:
        t0 = time.perf_counter()
        fired = sf.maintenance()
        pauses.append(time.perf_counter() - t0)
        if not fired:
            break
        # kNN keeps serving mid-plan, and stays *exact* against the live
        # set — each object visible exactly once in the pinned epoch
        d, _ = sf.knn(queries, k=1, max_frontier=512)
        np.testing.assert_allclose(np.asarray(d)[:, 0], 0.0, atol=1e-6)
        live = _forest_live_ids(sf.trees)
        assert live == sorted(set(live))
        assert live == alive.tolist()
    after = collect_stats(sf.trees)
    assert after.skew <= 1.2
    assert after.total == before.total
    assert sf.n_migration_steps >= 2           # genuinely incremental
    total_moved = sf.objects_migrated
    assert total_moved > 0
    for t in sf.trees:
        SMTreeEngine(t).validate()
    # every step is bounded; the worst single pause must beat the
    # stop-the-world rebuild by a wide margin (relative bound: absolute
    # wall-clock asserts flake on shared CI machines)
    assert max(pauses) < stop_world_s


def test_epoch_meta_tags_migration_publishes():
    sf, _, _ = _skewed_forest(migration_step_objects=32)
    assert sf.maintenance()                    # plan + step 0
    e = sf.epochs.epoch
    meta = sf.epochs.meta(e)
    assert meta is not None and meta["migration"]["step"] == 0
    sf.maintenance()
    assert sf.epochs.meta(sf.epochs.epoch)["migration"]["step"] == 1
    assert sf.epochs.meta(0) is None


# ---------------------------------------------------------------------------
# WAL + replay: control records, crash between steps, bitwise restore
# ---------------------------------------------------------------------------
def test_wal_migration_records_roundtrip(tmp_path):
    wal = WriteAheadLog(str(tmp_path / "wal"))
    plan = {"seed": 5, "steps": [[0, 1, [3, 4, 5]], [2, 1, [9]]]}
    wal.append_migration_plan(plan)
    wal.append_migration_step({"seed": 5, "step": 0})
    recs = list(iter_wal(str(tmp_path / "wal")))
    assert [r.kind for r in recs] == [KIND_MIGRATION_PLAN,
                                      KIND_MIGRATION_STEP]
    assert recs[0].params == plan
    assert recs[1].params == {"seed": 5, "step": 0}


def _drill(wal_dir, ckpt_dir, *, crash_after_steps, seed=9):
    """Skewed drill with interleaved inserts; snapshots mid-plan, then
    'crashes' after ``crash_after_steps`` further migration steps."""
    n, shards = 800, 4
    X = clustered(n, dims=DIM, seed=seed)
    rng = np.random.default_rng(seed)
    sf = StreamingForest(
        build_forest_trees(X, shards, capacity=8),
        wal=WriteAheadLog(wal_dir),
        ckpt=CheckpointManager(ckpt_dir) if ckpt_dir else None,
        max_skew=1.3, min_objects=64, rebalance_mode="incremental",
        migration_step_objects=24)
    victims = np.asarray([o for o in range(n) if o % shards == 0], np.int32)
    sf.delete_batch(X[victims], victims)
    sf.maintenance()                           # plan lands in the WAL
    assert sf.migration_active
    fresh = rng.normal(size=(40, DIM)).astype(np.float32)
    sf.insert_batch(fresh, np.arange(n, n + 40, dtype=np.int32))
    sf.maintenance()                           # step 1
    if ckpt_dir:
        sf.snapshot()                          # snapshot MID-PLAN
    for _ in range(crash_after_steps):
        sf.maintenance()
    return sf


def test_migration_crash_between_steps_restores_bitwise(tmp_path):
    wal_dir, ckpt_dir = str(tmp_path / "wal"), str(tmp_path / "ckpt")
    sf = _drill(wal_dir, ckpt_dir, crash_after_steps=2)
    assert sf.migration_active                 # killed mid-plan
    rest = StreamingForest.restore(
        ckpt_dir, wal=WriteAheadLog(wal_dir), max_skew=1.3, min_objects=64,
        migration_step_objects=24)
    assert rest.rebalance_mode == "incremental"
    assert rest.migration_active
    _trees_equal(sf.stacked(), rest.stacked())
    assert rest.owner == sf.owner
    assert tree_digest(tuple(rest.trees)) == tree_digest(tuple(sf.trees))
    # both resume the interrupted plan to completion identically (log=False:
    # the restored forest shares the WAL directory with the original — only
    # one writer may append, and this phase is about state equivalence)
    while sf.maintenance(log=False):
        pass
    while rest.maintenance(log=False):
        pass
    _trees_equal(sf.stacked(), rest.stacked())
    assert not sf.migration_active and not rest.migration_active


def test_restore_without_snapshot_replays_plan_records(tmp_path):
    """Cold restore (snapshot before the plan existed): the tail replays
    the plan record itself, then resumes from the recorded steps."""
    wal_dir, ckpt_dir = str(tmp_path / "wal"), str(tmp_path / "ckpt")
    n, shards = 800, 4
    X = clustered(n, dims=DIM, seed=11)
    sf = StreamingForest(
        build_forest_trees(X, shards, capacity=8),
        wal=WriteAheadLog(wal_dir), ckpt=CheckpointManager(ckpt_dir),
        max_skew=1.3, min_objects=64, rebalance_mode="incremental",
        migration_step_objects=24)
    sf.snapshot()                              # before any skew
    victims = np.asarray([o for o in range(n) if o % shards == 0], np.int32)
    sf.delete_batch(X[victims], victims)
    sf.maintenance()
    sf.maintenance()
    rest = StreamingForest.restore(
        ckpt_dir, wal=WriteAheadLog(wal_dir), max_skew=1.3, min_objects=64,
        migration_step_objects=24)
    _trees_equal(sf.stacked(), rest.stacked())
    assert rest.migration_active == sf.migration_active


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=0, max_value=10_000))
def test_migration_interleaving_replay_property(seed):
    """Property: arbitrary insert/delete batches interleaved with
    incremental migration end bitwise-equal to snapshot + WAL-tail
    restore, with the snapshot (and the implied crash) landing at a
    seed-chosen point — possibly mid-plan."""
    import tempfile
    rng = np.random.default_rng(seed)
    n, shards = 600, 4
    X = clustered(n, dims=DIM, seed=13)
    with tempfile.TemporaryDirectory() as root:
        wal_dir, ckpt_dir = f"{root}/wal", f"{root}/ckpt"
        sf = StreamingForest(
            build_forest_trees(X, shards, capacity=8),
            wal=WriteAheadLog(wal_dir), ckpt=CheckpointManager(ckpt_dir),
            max_skew=1.3, min_objects=64, rebalance_mode="incremental",
            migration_step_objects=16)
        live = set(range(n))
        next_id = n
        snap_at = int(rng.integers(2, 9))
        for step in range(10):
            if rng.random() < 0.6 and live:
                sk = int(rng.integers(0, shards))   # skewed deletes
                pool = [o for o in sorted(live) if o % shards == sk]
                take = pool[:int(rng.integers(1, 80))]
                if take:
                    oids = np.asarray(take, np.int32)
                    xs = np.stack([X[o] if o < n else
                                   np.zeros(DIM, np.float32) for o in take])
                    sf.delete_batch(xs, oids)
                    live -= set(take)
            else:
                b = int(rng.integers(1, 40))
                oids = np.arange(next_id, next_id + b, dtype=np.int32)
                sf.insert_batch(
                    rng.normal(size=(b, DIM)).astype(np.float32), oids)
                next_id += b
                live |= set(int(o) for o in oids)
            sf.maintenance()
            if step == snap_at:
                sf.snapshot()
        rest = StreamingForest.restore(
            ckpt_dir, wal=WriteAheadLog(wal_dir), max_skew=1.3,
            min_objects=64, migration_step_objects=16)
        _trees_equal(sf.stacked(), rest.stacked())
        assert rest.owner == sf.owner
        assert rest.migration_active == sf.migration_active


def test_step_replay_index_mismatch_is_loud(tmp_path):
    sf, _, _ = _skewed_forest(migration_step_objects=16)
    sf.maintenance()
    with pytest.raises(ValueError, match="does not match resume"):
        sf.apply_control(KIND_MIGRATION_STEP, {"seed": 0, "step": 5})


# ---------------------------------------------------------------------------
# replica followers replay migration records bitwise
# ---------------------------------------------------------------------------
def test_replica_follows_incremental_migration(tmp_path):
    from repro.stream.replica import Replica
    n, shards = 800, 4
    X = clustered(n, dims=DIM, seed=17)
    trees = build_forest_trees(X, shards, capacity=8)
    wal_dir = str(tmp_path / "wal")
    leader = StreamingForest([t for t in trees],
                             wal=WriteAheadLog(wal_dir),
                             max_skew=1.3, min_objects=64,
                             rebalance_mode="incremental",
                             migration_step_objects=32)
    follower = StreamingForest([t for t in trees], max_skew=1.3,
                               min_objects=64,
                               rebalance_mode="incremental",
                               migration_step_objects=32)
    rep = Replica(follower, wal_dir)
    victims = np.asarray([o for o in range(n) if o % shards == 0], np.int32)
    leader.delete_batch(X[victims], victims)
    leader.maintenance()                       # plan + step 0
    rep.run_until(leader.wal.next_seq - 1)
    assert follower.migration_active
    while leader.maintenance():
        rep.run_until(leader.wal.next_seq - 1)
    assert not follower.migration_active
    _trees_equal(leader.stacked(), follower.stacked())
    assert tree_digest(tuple(follower.trees)) == \
        tree_digest(tuple(leader.trees))
    assert follower.owner == leader.owner


# ---------------------------------------------------------------------------
# front-end scheduler slot drives migration between mutation batches
# ---------------------------------------------------------------------------
def test_frontend_maintenance_slot_runs_migration():
    from repro.serve.frontend import FrontendConfig, ServeFrontend
    n, shards = 800, 4
    X = clustered(n, dims=DIM, seed=19)
    sf = StreamingForest(build_forest_trees(X, shards, capacity=8),
                         max_skew=1.3, min_objects=64,
                         rebalance_mode="incremental",
                         migration_step_objects=32)
    fe = ServeFrontend(sf, FrontendConfig(cohort_width=8, slo_ms=2.0,
                                          k=4)).start()
    try:
        victims = [o for o in range(n) if o % shards < 2]
        for c in range(0, len(victims), 64):
            chunk = np.asarray(victims[c:c + 64], np.int32)
            fe.submit_mutations(
                np.full(len(chunk), OP_DELETE, np.int32), X[chunk], chunk)
        fe.drain()
        # drain() guarantees every batch applied — and each batch offered
        # the engine one maintenance slot, so the plan is progressing (or
        # already done) without any explicit maintenance() call here
        assert fe.stats.n_maintenance > 0
        assert sf.n_migration_steps > 0
        while sf.migration_active:
            sf.maintenance()
        assert collect_stats(sf.trees).skew <= 1.3
    finally:
        fe.stop()
