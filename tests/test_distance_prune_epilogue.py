"""Fused distance+prune Pallas epilogue: interpret-mode parity of the
in-kernel triangle-inequality mask against the jnp reference, for all three
metrics, including rows engineered to sit exactly on the prune boundary
(the ``_EPS`` regime core/smtree.py pads for)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

METRICS = ["d_inf", "sqeuclidean", "ip"]


def _true_dist(dist, metric):
    """Kernel distances -> the distances the mask is defined on (the fused
    epilogue applies sqrt in-kernel for sqeuclidean)."""
    d = np.asarray(dist)
    return np.sqrt(np.maximum(d, 0.0)) if metric == "sqeuclidean" else d


@pytest.mark.parametrize("nq,ne,d", [(32, 48, 16), (100, 130, 20), (7, 257, 96)])
@pytest.mark.parametrize("metric", METRICS)
def test_prune_mask_matches_reference(nq, ne, d, metric):
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(nq * 31 + ne), 4)
    q = jax.random.uniform(k1, (nq, d))
    e = jax.random.uniform(k2, (ne, d))
    # 'ip' distances are negative inner products (~ -d/4 for uniform [0,1]
    # vectors): centre the radii near each metric's distance range so the
    # mask is a real mix of True/False
    if metric == "ip":
        lo, hi = -0.2 * d, -0.05 * d
    elif metric == "sqeuclidean":          # true L2 dist ~ 0.41 * sqrt(d)
        lo, hi = 0.1 * d ** 0.5, 0.35 * d ** 0.5
    else:
        lo, hi = 0.0, 0.6
    r_q = jax.random.uniform(k3, (nq,), minval=lo, maxval=hi)
    r_e = jax.random.uniform(k4, (ne,), minval=lo, maxval=hi)

    got_d, got_m = ops.pairwise_distance_prune(q, e, r_q, r_e, metric=metric,
                                               impl="interpret")
    want_m = ref.prune_mask_ref(jnp.asarray(_true_dist(got_d, metric)),
                                r_q, r_e)
    assert np.asarray(got_m).dtype == np.bool_
    # away from the float boundary the kernel mask must agree exactly
    margin = np.abs(_true_dist(got_d, metric)
                    - (np.asarray(r_q)[:, None] + np.asarray(r_e)[None, :]))
    decided = margin > 1e-6
    assert decided.mean() > 0.95, "degenerate case: almost all borderline"
    np.testing.assert_array_equal(np.asarray(got_m)[decided],
                                  np.asarray(want_m)[decided])
    # both mask populations must be represented, else the test proves nothing
    assert np.asarray(got_m)[decided].any()
    assert (~np.asarray(got_m)[decided]).any()


@pytest.mark.parametrize("metric", METRICS)
def test_prune_mask_exact_boundary_is_inclusive(metric):
    """Rows constructed so d == r_q + r_e exactly: the paper's prune test is
    inclusive (survive on equality), matching prune_mask_ref.  This is the
    borderline the engine additionally pads with _EPS (core/smtree.py) —
    the kernel itself must already be inclusive, the engine epsilon only
    absorbs f32 radius-fold rounding on top."""
    d = 32
    q = jnp.zeros((8, d), jnp.float32)
    # entries at exactly-representable distances from the origin
    offsets = jnp.asarray([0.25, 0.5, 1.0, 2.0], jnp.float32)
    e = jnp.zeros((4, d), jnp.float32).at[:, 0].set(offsets)
    if metric == "d_inf":
        dist = offsets                       # max |q - e|
    elif metric == "sqeuclidean":
        dist = offsets                       # true (sqrt'd) distance
    else:                                    # ip: -<q, e> = 0 for q = 0
        dist = jnp.zeros((4,), jnp.float32)
    # split d into r_q + r_e in exactly-representable halves
    r_q = jnp.full((8,), float(dist[0]) * 0.5, jnp.float32)
    r_e = dist - float(dist[0]) * 0.5        # r_q + r_e == dist exactly

    got_d, got_m = ops.pairwise_distance_prune(q, e, r_q, r_e, metric=metric,
                                               impl="interpret")
    want_d, want_m = ops.pairwise_distance_prune(q, e, r_q, r_e, metric=metric,
                                                 impl="xla")
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want_d),
                               rtol=1e-6, atol=1e-6)
    # exact boundary: d <= r_q + r_e holds with equality -> all True,
    # in both the fused kernel and the reference
    assert np.asarray(got_m).all(), np.asarray(got_m)
    assert np.asarray(want_m).all()


def test_prune_mask_eps_padding_visits_borderline_subtrees():
    """The engine-level guarantee _EPS exists for: a distance one ulp above
    the folded radius bound must still survive once the caller pads r_q by
    _EPS (smtree.py queries do exactly this)."""
    from repro.core.smtree import _EPS
    d = 16
    q = jnp.zeros((1, d), jnp.float32)
    e = jnp.zeros((1, d), jnp.float32).at[0, 0].set(1.0)
    ulp = float(np.spacing(np.float32(1.0)))
    # radius bound sits one f32 ulp BELOW the true distance: un-padded test
    # prunes, _EPS-padded test (the engine's form) must keep the subtree
    r_e = jnp.asarray([1.0 - ulp - 0.5], jnp.float32)
    strict = ops.pairwise_distance_prune(q, e, jnp.asarray([0.5]), r_e,
                                         metric="d_inf", impl="interpret")[1]
    padded = ops.pairwise_distance_prune(q, e, jnp.asarray([0.5 + _EPS]), r_e,
                                         metric="d_inf", impl="interpret")[1]
    assert not bool(np.asarray(strict)[0, 0])
    assert bool(np.asarray(padded)[0, 0])
