"""Error-feedback wiring for int8 gradient compression (ROADMAP item).

Two layers: (1) the algebraic EF property — with the residual threaded
back in, the running sum of dequantized gradients tracks the running sum
of true gradients to within ~one quantisation step, i.e. the quantisation
error is a delayed correction, not a bias that compounds; (2) the train
step — ``TrainSettings(error_feedback=True)`` carries persistent EF state
through ``make_train_step`` and converges on par with uncompressed
training on a smoke config.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.compression import (compressed_mean_hook, compressed_psum_mean,
                                    init_ef_state)


def test_hook_ef_error_bounded_over_steps():
    rng = np.random.default_rng(0)
    g0 = rng.normal(size=(256,)).astype(np.float32)
    grads = {"w": jnp.asarray(g0)}
    ef = init_ef_state(grads)
    acc_true = np.zeros_like(g0)
    acc_q = np.zeros_like(g0)
    worst = 0.0
    for i in range(50):
        gi = {"w": jnp.asarray(g0 * (1.0 + 0.02 * np.sin(i)))}
        out, ef = compressed_mean_hook(gi, ef=ef)
        acc_true += np.asarray(gi["w"])
        acc_q += np.asarray(out["w"])
        worst = max(worst, float(np.abs(acc_true - acc_q).max()))
    # one quantisation step of the largest per-step gradient, not O(steps)
    step_scale = 1.02 * np.abs(g0).max() / 127
    assert worst <= 2.5 * step_scale, (worst, step_scale)
    # without EF the same accumulation drifts measurably more
    acc_q0 = np.zeros_like(g0)
    for i in range(50):
        gi = {"w": jnp.asarray(g0 * (1.0 + 0.02 * np.sin(i)))}
        out = compressed_mean_hook(gi)
        acc_q0 += np.asarray(out["w"])
    assert np.abs(acc_true - acc_q0).max() >= worst


def test_hook_ef_none_mode_passthrough():
    g = {"w": jnp.ones((4,))}
    ef = init_ef_state(g)
    out, ef2 = compressed_mean_hook(g, mode="none", ef=ef)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.ones(4))
    assert ef2 is ef
    # legacy no-EF call shape unchanged
    out2 = compressed_mean_hook(g, mode="none")
    assert isinstance(out2, dict)


def test_psum_mean_accepts_ef():
    # single-axis shard_map with one device: EF residual folds in and the
    # returned err is the next state
    from repro.dist.sharding import shard_map
    from jax.sharding import PartitionSpec as P
    import functools
    mesh = jax.make_mesh((1,), ("data",))
    g = np.linspace(-1, 1, 64).astype(np.float32)[None]
    ef0 = np.full((1, 64), 0.003, np.float32)

    @functools.partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                       out_specs=(P("data"), P("data")), check_rep=False)
    def run(gs, efs):
        mean, err = compressed_psum_mean({"g": gs}, "data", ef={"g": efs})
        return mean["g"], err["g"]

    mean, err = run(jnp.asarray(g), jnp.asarray(ef0))
    scale = np.abs(g + ef0).max() / 127
    # mean ~ g + ef within one quantisation step; err is the new residual
    assert np.abs(np.asarray(mean) - (g + ef0)).max() <= scale * 1.01
    np.testing.assert_allclose(np.asarray(mean) + np.asarray(err), g + ef0,
                               atol=1e-6)


def test_train_step_ef_convergence_parity():
    """Smoke parity: int8+EF training loss trajectory stays close to
    uncompressed; the EF state is nonzero (it is actually wired) and the
    step round-trips params/opt/ef through jit."""
    from repro.configs.all_archs import smoke_config
    from repro.data.pipeline import DataConfig, synth_batch
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import (TrainSettings, init_all,
                                        make_train_step)

    cfg = dataclasses.replace(smoke_config("qwen2.5-3b"), n_layers=1,
                              block_pattern=("attn",))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    batch0 = synth_batch(dc, 0)
    inputs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
              for k, v in batch0.items()}
    opt = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=40)
    steps = 10

    def run(settings):
        step_fn, _ = make_train_step(cfg, mesh, inputs, settings)
        ef_mode = settings.error_feedback
        state = init_all(cfg, jax.random.PRNGKey(0), error_feedback=ef_mode)
        jitted = jax.jit(step_fn)
        losses = []
        if ef_mode:
            params, opt_state, ef = state
            for s in range(steps):
                params, opt_state, ef, m = jitted(params, opt_state, ef,
                                                  synth_batch(dc, s))
                losses.append(float(m["loss"]))
            return losses, ef
        params, opt_state = state
        for s in range(steps):
            params, opt_state, m = jitted(params, opt_state,
                                          synth_batch(dc, s))
            losses.append(float(m["loss"]))
        return losses, None

    base, _ = run(TrainSettings(opt=opt))
    efl, ef = run(TrainSettings(opt=opt, grad_compression="int8",
                                error_feedback=True))
    assert np.isfinite(base).all() and np.isfinite(efl).all()
    assert base[-1] < base[0] and efl[-1] < efl[0], (base, efl)
    # parity: compressed+EF tracks uncompressed within a loose band on
    # this smoke config (quantisation noise, not divergence)
    assert abs(efl[-1] - base[-1]) < 0.15 * abs(base[0]), (base, efl)
    # the EF state actually carries residuals
    ef_mag = max(float(jnp.abs(e).max()) for e in jax.tree.leaves(ef))
    assert ef_mag > 0.0
