"""Front-end unit tests: deadline-or-batch-full dispatch, pad-to-width
fixed geometry, epoch tagging, the mutation scheduler, and the
KnnLmDatastore published-epoch resync regression."""
import threading
import time

import numpy as np
import pytest

from repro.core.metric import pairwise
from repro.core.smtree import OP_INSERT, ST_APPLIED, bulk_build
from repro.serve.frontend import FrontendConfig, ServeFrontend, pinned_knn
from repro.stream import StreamingEngine

N, DIM = 384, 6


def _engine(seed=0, n=N):
    X = np.random.default_rng(seed).random((n, DIM)).astype(np.float32)
    return StreamingEngine(bulk_build(X, capacity=8)), X


def test_full_cohort_dispatches_immediately():
    eng, X = _engine()
    Q = np.random.default_rng(1).random((8, DIM)).astype(np.float32)
    cfg = FrontendConfig(cohort_width=8, slo_ms=10_000.0, k=3,
                         max_frontier=256)
    with ServeFrontend(eng, cfg) as fe:
        d, ids = fe.knn(Q)     # exactly one full-width cohort
    want = np.sort(pairwise(eng.tree.metric, Q, X), axis=1)[:, :3]
    np.testing.assert_allclose(d, want, atol=1e-5)
    assert fe.stats.n_cohorts == 1
    assert fe.stats.n_full_dispatch == 1
    assert fe.stats.n_deadline_dispatch == 0
    assert fe.stats.mean_fill == 8


def test_partial_cohort_ships_at_deadline():
    eng, X = _engine()
    Q = np.random.default_rng(2).random((3, DIM)).astype(np.float32)
    cfg = FrontendConfig(cohort_width=8, slo_ms=40.0, k=2, max_frontier=256)
    with ServeFrontend(eng, cfg) as fe:
        tickets = fe.submit_many(Q)        # 3 < width: only the SLO fires
        out = [t.result(30) for t in tickets]
    d = np.stack([d for d, _ in out])
    want = np.sort(pairwise(eng.tree.metric, Q, X), axis=1)[:, :2]
    np.testing.assert_allclose(d, want, atol=1e-5)   # pad rows discarded
    assert fe.stats.n_deadline_dispatch >= 1
    assert fe.stats.n_queries == 3


def test_tickets_record_their_epoch_and_see_publishes():
    eng, X = _engine()
    cfg = FrontendConfig(cohort_width=1, slo_ms=5.0, k=1, max_frontier=256)
    newpt = np.full((1, DIM), 0.5, np.float32)
    with ServeFrontend(eng, cfg) as fe:
        tk0 = fe.submit(newpt[0])
        tk0.result(30)
        assert tk0.epoch == 0
        mt = fe.submit_mutations(np.full(1, OP_INSERT, np.int32), newpt,
                                 np.array([N], np.int32))
        res = mt.result(30)
        assert (res.statuses == ST_APPLIED).all()
        tk1 = fe.submit(newpt[0])
        d, ids = tk1.result(30)
        assert tk1.epoch == 1
        assert ids[0] == N and d[0] <= 1e-6   # the insert is visible now


def test_cohort_error_fails_its_tickets():
    eng, _ = _engine()

    def bad_knn(pinned, q):
        raise RuntimeError("descent exploded")

    cfg = FrontendConfig(cohort_width=1, slo_ms=5.0)
    with ServeFrontend(eng, cfg, knn_fn=bad_knn) as fe:
        tk = fe.submit(np.zeros(DIM, np.float32))
        with pytest.raises(RuntimeError, match="descent exploded"):
            tk.result(30)


def test_stop_drains_and_rejects_new_work():
    eng, _ = _engine()
    fe = ServeFrontend(eng, FrontendConfig(cohort_width=4, slo_ms=20.0,
                                           k=1)).start()
    tickets = fe.submit_many(np.zeros((6, DIM), np.float32))
    fe.stop()                       # drain=True: everything admitted serves
    assert all(t.done() and t.err is None for t in tickets)
    with pytest.raises(RuntimeError):
        fe.submit(np.zeros(DIM, np.float32))
    with pytest.raises(RuntimeError):
        fe.submit_mutations(np.zeros(1, np.int32), np.zeros((1, DIM)),
                            np.zeros(1, np.int32))


def test_pinned_knn_forest_merge():
    from repro.core.distributed import build_forest_trees
    X = np.random.default_rng(5).random((400, DIM)).astype(np.float32)
    shards = tuple(build_forest_trees(X, 2, capacity=8))
    d, ids = pinned_knn(shards, X[:10] + 0.001, k=3, max_frontier=256)
    want = np.sort(pairwise(shards[0].metric, X[:10] + 0.001, X),
                   axis=1)[:, :3]
    np.testing.assert_allclose(d, want, atol=1e-5)


# -- KnnLmDatastore regression: engine reads come from the published epoch


def _store():
    from repro.serve.knnlm import KnnLmConfig, KnnLmDatastore
    rng = np.random.default_rng(7)
    keys = rng.random((256, DIM)).astype(np.float32)
    vals = rng.integers(0, 50, 256).astype(np.int32)
    store = KnnLmDatastore(KnnLmConfig(k=3, capacity=8, metric="l2"), DIM)
    store.build(keys, vals)
    return store, rng


def test_knnlm_sync_uses_published_epoch_not_working_tree():
    """Regression: ``engine.tree`` must resync from the *published* epoch.
    ``stream.tree`` is the batcher's live working reference — mid-batch it
    holds half-applied cohorts no reader may observe."""
    store, rng = _store()
    store.enable_stream()
    published = store.stream.epochs.current()[1]
    # simulate the mid-batch window: the batcher's working tree runs ahead
    # of the last publish
    store.stream.batcher.tree = bulk_build(
        rng.random((64, DIM)).astype(np.float32), capacity=8)
    assert store.stream.tree is not published
    store._sync_engine_tree()
    assert store.engine.tree is published


def test_knnlm_add_evict_resync_published():
    store, rng = _store()
    store.enable_stream()
    oids = store.add_batch(rng.random((8, DIM)).astype(np.float32),
                           rng.integers(0, 50, 8).astype(np.int32))
    assert store.engine.tree is store.stream.epochs.current()[1]
    assert store.evict_batch(oids[:4]) == 4
    assert store.engine.tree is store.stream.epochs.current()[1]


def test_knnlm_frontend_roundtrip():
    import jax.numpy as jnp
    store, rng = _store()
    store.enable_stream()
    store.enable_frontend(cohort_width=4, slo_ms=20.0)
    try:
        h = rng.random((4, DIM)).astype(np.float32)
        logp = store.knn_logits(jnp.asarray(h), 50)
        assert logp.shape == (4, 50)
        assert np.isfinite(np.asarray(logp)).all()
        oids = store.add_batch(rng.random((4, DIM)).astype(np.float32),
                               rng.integers(0, 50, 4).astype(np.int32))
        assert store.evict_batch(oids) == 4    # rows *submitted*
        store.frontend.drain(timeout=60)
        assert store.frontend.stats.n_mutation_batches == 2
        # submit-time resyncs may lag the async applies; a fresh sync
        # must land exactly on the now-published epoch
        store._sync_engine_tree()
        assert store.engine.tree is store.stream.epochs.current()[1]
    finally:
        store.close_frontend()
    assert store.frontend is None


def test_shed_policy_raises_queue_full_with_hint():
    from repro.serve.frontend import QueueFull
    eng, X = _engine()
    # width never fills and the SLO is huge, so admitted queries park
    cfg = FrontendConfig(cohort_width=64, slo_ms=60_000.0, k=2,
                         max_frontier=256, queue_cap=3, overload="shed")
    fe = ServeFrontend(eng, cfg).start()
    try:
        q = np.random.default_rng(7).random(DIM).astype(np.float32)
        tickets = [fe.submit(q) for _ in range(3)]
        with pytest.raises(QueueFull) as ei:
            fe.submit(q)
        assert ei.value.retry_after_s > 0       # actionable hint
        assert fe.stats.n_shed == 1
        assert fe.stats.snapshot()["queue_depth"] == 3
        assert fe.stats.snapshot()["n_shed"] == 1
    finally:
        fe.stop(drain=False)
    assert all(t.done() for t in tickets)       # failed by stop, not lost


def test_shed_policy_caps_mutation_queue():
    from repro.core.smtree import OP_NOP
    from repro.serve.frontend import QueueFull
    eng, X = _engine()
    cfg = FrontendConfig(cohort_width=4, slo_ms=5.0, mutation_queue_cap=2,
                         overload="shed")
    fe = ServeFrontend(eng, cfg)
    fe._running = True              # queues only: workers never drain
    ops = np.full(1, OP_NOP, np.int32)
    xs = np.zeros((1, DIM), np.float32)
    oid = np.array([0], np.int32)
    fe.submit_mutations(ops, xs, oid)
    fe.submit_mutations(ops, xs, oid)
    with pytest.raises(QueueFull):
        fe.submit_mutations(ops, xs, oid)
    assert fe.stats.snapshot()["mutation_queue_depth"] == 2
    fe._running = False


def test_block_policy_unchanged_under_cap():
    """Default policy still blocks (and then succeeds) rather than shed."""
    eng, X = _engine()
    cfg = FrontendConfig(cohort_width=2, slo_ms=5.0, k=2, max_frontier=256,
                         queue_cap=2)
    with ServeFrontend(eng, cfg) as fe:
        Q = np.random.default_rng(8).random((10, DIM)).astype(np.float32)
        tickets = fe.submit_many(Q)     # > cap: submit blocks, never raises
        for t in tickets:
            t.result(30)
    assert fe.stats.n_queries == 10
    assert fe.stats.n_shed == 0
