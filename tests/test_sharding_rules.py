"""Unit tests for the GSPMD sharding policy (no device mesh needed beyond
host CPU — rules are pure functions of paths/shapes/mesh shape)."""
import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import get_config
from repro.dist import sharding as shd
from repro.models import model as M


class FakeMesh:
    """Duck-typed mesh (axis names/sizes only) for rule unit tests."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = FakeMesh({"data": 16, "model": 16})


def _specs(arch, **over):
    cfg = get_config(arch, head_pad=16, vocab_pad_to=256, **over)
    return cfg, shd.param_pspecs(cfg, M.param_specs(cfg), MESH)


def _flat(specs):
    return {("/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                      for p in path)): s
            for path, s in jax.tree_util.tree_flatten_with_path(
                specs, is_leaf=lambda x: isinstance(x, P))[0]}


def test_every_spec_divides_evenly():
    """jit argument shardings demand exact divisibility for all archs."""
    from repro.configs.base import list_archs
    for arch in list_archs():
        cfg = get_config(arch, head_pad=16, vocab_pad_to=256)
        sds = M.param_specs(cfg)
        specs = shd.param_pspecs(cfg, sds, MESH)
        for (path, leaf), (_, spec) in zip(
                jax.tree_util.tree_flatten_with_path(sds)[0],
                jax.tree_util.tree_flatten_with_path(
                    specs, is_leaf=lambda x: isinstance(x, P))[0]):
            for i, part in enumerate(spec):
                if part is None:
                    continue
                total = 1
                for a in (part if isinstance(part, tuple) else (part,)):
                    total *= MESH.shape[a]
                assert leaf.shape[i] % total == 0, \
                    (arch, path, leaf.shape, spec)


def test_attention_rules():
    _, specs = _specs("yi-34b")
    flat = _flat(specs)
    wq = next(v for k, v in flat.items() if k.endswith("attn/wq"))
    assert wq[-2] == "model", wq                  # heads sharded
    wk = next(v for k, v in flat.items() if k.endswith("attn/wk"))
    assert "model" not in [a for p in wk if p for a in
                           (p if isinstance(p, tuple) else (p,))], wk


def test_embed_vocab_sharded_no_fsdp():
    _, specs = _specs("qwen2.5-3b")
    flat = _flat(specs)
    emb = flat["embed"]
    assert emb[0] == "model" and (len(emb) < 2 or emb[1] is None), emb


def test_moe_ep_switches_expert_axis():
    _, specs = _specs("grok-1-314b")
    flat = _flat(specs)
    wi = next(v for k, v in flat.items() if k.endswith("moe/wi"))
    assert wi[-1] == "model" and wi[-3] != "data", wi   # TP + FSDP on D
    _, specs_ep = _specs("grok-1-314b", moe_ep=True, expert_pad_to=16)
    flat_ep = _flat(specs_ep)
    wi_ep = next(v for k, v in flat_ep.items() if k.endswith("moe/wi"))
    assert wi_ep[-3] == "data", wi_ep                    # E over data (EP)


def test_zero1_extends_with_data():
    spec = shd.opt_state_pspec(P(None, "model"), (4096, 1024), MESH)
    assert spec[0] == "data" and spec[1] == "model", spec


def test_big_params_get_fsdp():
    _, specs = _specs("yi-34b")
    flat = _flat(specs)
    wq = next(v for k, v in flat.items() if k.endswith("attn/wq"))
    used = [a for p in wq if p for a in (p if isinstance(p, tuple) else (p,))]
    assert "data" in used, wq    # 7168x64x128 > threshold -> FSDP'd


def test_cache_specs_seq_sharding():
    cfg = get_config("jamba-v0.1-52b", head_pad=16, vocab_pad_to=256)
    from repro.configs.base import SHAPES
    cache = M.cache_specs(cfg, SHAPES["long_500k"])
    specs = shd.cache_pspecs(cfg, cache, MESH, seq_shard=True)
    kv_specs = [s for s in jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, P)) if len(s) == 5]
    assert kv_specs, "jamba must have KV caches"
    for s in kv_specs:
        assert s[3] is not None, s    # sequence axis sharded
