"""Shared test configuration.

Provides a minimal deterministic stand-in for ``hypothesis`` when it is not
installed (some minimal images ship only jax+numpy+pytest; CI installs the
real library from pyproject.toml).  The stub supports exactly the subset the
suite uses — ``given``/``settings`` and the ``integers``/``sampled_from``
strategies — drawing seeded pseudo-random examples so the property tests
still exercise many cases and stay reproducible.
"""
import inspect
import random
import sys
import types


def _install_hypothesis_stub() -> None:
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    mod.__hypothesis_stub__ = True

    def integers(min_value, max_value):
        return ("int", min_value, max_value)

    def sampled_from(seq):
        return ("sample", list(seq))

    def _draw(rng, strat):
        if strat[0] == "int":
            return rng.randint(strat[1], strat[2])
        return rng.choice(strat[1])

    def given(*strats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_stub_max_examples", 20)
                rng = random.Random(f"stub:{fn.__module__}.{fn.__qualname__}")
                for _ in range(n):
                    fn(*args, *(_draw(rng, s) for s in strats), **kwargs)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            # strategy-filled params must be invisible to pytest's fixture
            # resolution (real hypothesis does the same)
            wrapper.__signature__ = inspect.Signature(parameters=[])
            return wrapper
        return deco

    def settings(max_examples=20, deadline=None, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    st.integers = integers
    st.sampled_from = sampled_from
    mod.given = given
    mod.settings = settings
    mod.strategies = st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_stub()
else:
    # Weekly CI runs the property tests much deeper than the PR gate:
    # select with --hypothesis-profile=nightly (real hypothesis only; the
    # stub above ignores profiles and keeps its fixed example budget).
    from hypothesis import settings

    settings.register_profile("nightly", max_examples=500, deadline=None)
